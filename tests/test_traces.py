"""Trace generator properties (paper §V-E workloads)."""
import collections

from repro.traces import make_adapters, production_trace, six_traces, \
    synth_trace


def test_make_adapters_counts_and_powerlaw():
    ads = make_adapters(100, alpha=1.0, seed=0)
    assert len(ads) == 100
    by_rank = collections.Counter(a.rank for a in ads)
    # power law on counts: rank-8 most numerous
    assert by_rank[8] == max(by_rank.values())
    assert set(by_rank) == {8, 16, 32, 64, 128}


def test_synth_trace_rates():
    ads = make_adapters(20, seed=0)
    tr = synth_trace(ads, rps=10, duration=60, arrival="uniform", seed=1)
    assert abs(len(tr) - 600) <= 1
    assert all(0 <= r.arrival < 60 for r in tr)
    tr = synth_trace(ads, rps=10, duration=60, arrival="poisson", seed=1)
    assert 400 < len(tr) < 800


def test_shifting_skew_direction():
    """Fig 16: rank-128 dominates early, rank-8 dominates late."""
    ads = make_adapters(50, seed=0)
    tr = synth_trace(ads, rps=50, duration=200, popularity="shifting",
                     seed=2)
    early = [r for r in tr if r.arrival < 40]
    late = [r for r in tr if r.arrival > 160]
    frac128_early = sum(r.rank == 128 for r in early) / len(early)
    frac128_late = sum(r.rank == 128 for r in late) / len(late)
    assert frac128_early > 0.35
    assert frac128_late < 0.22
    frac8_late = sum(r.rank == 8 for r in late) / len(late)
    assert frac8_late > 0.35


def test_exponential_popularity_prefers_small_ranks():
    ads = make_adapters(50, seed=0)
    tr = synth_trace(ads, rps=50, duration=100, popularity="exponential",
                     seed=3)
    by_rank = collections.Counter(r.rank for r in tr)
    assert by_rank[8] > by_rank[128]


def test_six_traces_grid():
    ads = make_adapters(25, seed=0)
    traces = six_traces(ads, rps=5, duration=30)
    assert len(traces) == 6
    assert all(len(t) > 0 for t in traces.values())


def test_production_trace_heavy_tail():
    """Fig 8: top-5 adapters take the large majority of requests."""
    tr = production_trace(100, rps=50, duration=120, seed=4)
    counts = collections.Counter(r.adapter_id for r in tr)
    top5 = sum(c for _, c in counts.most_common(5))
    assert top5 / len(tr) > 0.55
    ranks = collections.Counter(r.rank for r in tr)
    assert ranks[8] > ranks[128]  # Fig 15 rank share ordering
