"""Streaming serving gateway: SSE ordering + token parity vs the batch
path, runtime adapter lifecycle over HTTP, per-tenant admission
fairness, graceful drain with zero lost tokens (both substrates), the
incremental cluster API itself, and snapshot-safe report percentiles."""
import asyncio
import copy
import http.client
import json
import math
import os
import random
import signal
import subprocess
import sys
import threading
import time

import pytest

import jax

from repro.cluster import NetworkModel
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, ServeRequest, UnknownAdapterError
from repro.models import model as M
from repro.serving import (ClusterReport, EngineBackend,
                           LoRAServeCluster, SimBackend)
from repro.server import AdmissionController, ServeGateway


# ---------------------------------------------------------------------
# harness: run the asyncio gateway in a thread, drive it over real HTTP
# ---------------------------------------------------------------------
class GatewayHarness:
    def __init__(self, cluster, **kw):
        self.gw = ServeGateway(cluster, port=0, **kw)
        self._ready = threading.Event()
        self.loop = None
        self.thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        self.loop = asyncio.new_event_loop()
        asyncio.set_event_loop(self.loop)

        async def main():
            await self.gw.start()
            self._ready.set()
            await self.gw.serve_until_stopped()

        try:
            self.loop.run_until_complete(main())
        finally:
            self.loop.close()

    def __enter__(self):
        self.thread.start()
        assert self._ready.wait(60), "gateway failed to start"
        return self

    def shutdown(self, timeout=120):
        """The SIGTERM path: ``begin_shutdown`` is exactly what the
        installed signal handler invokes."""
        if self.thread.is_alive():
            self.loop.call_soon_threadsafe(self.gw.begin_shutdown)
            self.thread.join(timeout)
        assert not self.thread.is_alive(), "gateway failed to drain"

    def __exit__(self, *exc):
        self.shutdown()

    @property
    def port(self):
        return self.gw.port


def http_json(port, method, path, body=None, headers=None):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=120)
    hdrs = {"Content-Type": "application/json"}
    hdrs.update(headers or {})
    conn.request(method, path,
                 json.dumps(body) if body is not None else None, hdrs)
    resp = conn.getresponse()
    raw = resp.read()
    out_headers = {k.lower(): v for k, v in resp.getheaders()}
    conn.close()
    try:
        parsed = json.loads(raw) if raw else {}
    except ValueError:
        parsed = raw.decode("utf-8", "replace")
    return resp.status, parsed, out_headers


def sse_request(port, payload, headers=None):
    """POST /v1/completions with stream=true; returns (status, chunks)
    where chunks are the decoded SSE frames up to ``[DONE]``."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=300)
    hdrs = {"Content-Type": "application/json"}
    hdrs.update(headers or {})
    conn.request("POST", "/v1/completions", json.dumps(payload), hdrs)
    resp = conn.getresponse()
    if resp.status != 200:
        resp.read()
        conn.close()
        return resp.status, []
    chunks = []
    while True:
        line = resp.fp.readline()
        if not line:
            break
        line = line.decode("utf-8").strip()
        if not line.startswith("data: "):
            continue
        data = line[len("data: "):]
        if data == "[DONE]":
            break
        chunks.append(json.loads(data))
    conn.close()
    return 200, chunks


def tokens_of(chunks):
    out = []
    for c in chunks:
        out.extend(c.get("tokens") or [])
    return out


def make_sim_cluster(n_servers=2, n_adapters=4, seed=0, **kw):
    adapters = [AdapterInfo(f"a{i}-r{[8, 16, 32, 64][i % 4]}",
                            [8, 16, 32, 64][i % 4], nbytes=8 << 20)
                for i in range(n_adapters)]
    backend = SimBackend(n_servers, adapter_nbytes={
        a.adapter_id: a.nbytes for a in adapters})
    return LoRAServeCluster(backend, adapters,
                            network=NetworkModel(),
                            rebalance_period=kw.pop("rebalance_period",
                                                    1e9),
                            seed=seed, **kw), adapters


# ---------------------------------------------------------------------
# incremental cluster API (no HTTP): run() === submit/poll/drain
# ---------------------------------------------------------------------
def test_incremental_api_matches_batch_run():
    """Driving the same trace through submit/poll/drain reproduces the
    batch ``run()`` exactly: same routing, completions, and TTFTs —
    ``run`` really is a client of the incremental API."""
    def trace():
        rng = random.Random(3)
        return [ServeRequest(req_id=i, adapter_id=f"a{rng.randrange(4)}-"
                             f"r{[8, 16, 32, 64][rng.randrange(4) % 4]}",
                             prompt_len=16, output_len=6,
                             arrival=i * 0.02)
                for i in range(12)]

    # adapter ids in the synthetic trace must exist: build from the set
    reqs = trace()
    ranks = {r.adapter_id: int(r.adapter_id.split("-r")[1])
             for r in reqs}
    adapters = [AdapterInfo(aid, rk, nbytes=8 << 20)
                for aid, rk in sorted(ranks.items())]

    def make():
        be = SimBackend(2, adapter_nbytes={a.adapter_id: a.nbytes
                                           for a in adapters})
        return LoRAServeCluster(be, adapters,
                                network=NetworkModel(), seed=5)

    batch = make()
    batch_rep = batch.run(copy.deepcopy(reqs))

    inc = make()
    inc.start()
    todo = sorted(copy.deepcopy(reqs), key=lambda r: r.arrival)
    i, now = 0, 0.0
    while i < len(todo) or inc.pending():
        while i < len(todo) and todo[i].arrival <= now + 1e-12:
            inc.submit(todo[i], now)
            i += 1
        inc.poll(now)
        nxt = inc._next_time(now, i < len(todo),
                             todo[i].arrival if i < len(todo) else None)
        if nxt is None:
            break
        now = max(now, nxt)
    inc.drain()
    inc_rep = inc.report()

    assert inc.routed == batch.routed
    assert inc_rep.completed() == batch_rep.completed() == len(reqs)
    assert sorted(r.ttft for r in inc_rep.results) == \
        sorted(r.ttft for r in batch_rep.results)


def test_cluster_register_unregister_lifecycle():
    cluster, _ = make_sim_cluster()
    cluster.start()
    sid = cluster.register_adapter(AdapterInfo("newbie", 16,
                                               nbytes=8 << 20))
    assert "newbie" in cluster.meta
    assert cluster.orch.placement["newbie"] == {sid: 1.0}
    cluster.submit(ServeRequest(req_id=1, adapter_id="newbie",
                                prompt_len=8, output_len=4,
                                arrival=0.0), 0.0)
    evs = cluster.drain()
    assert any(e.kind == "finish" and e.req.req_id == 1 for e in evs)

    cluster.unregister_adapter("newbie")
    with pytest.raises(UnknownAdapterError):
        cluster.submit(ServeRequest(req_id=2, adapter_id="newbie",
                                    prompt_len=8, output_len=4,
                                    arrival=0.0), 0.0)
    cluster.drain()
    assert "newbie" not in cluster.meta
    assert "newbie" not in cluster.orch.store.meta
    rep = cluster.report()
    assert rep.registered == 1 and rep.unregistered == 1
    # double-unregister and unknown both raise the routing error
    with pytest.raises(UnknownAdapterError):
        cluster.unregister_adapter("newbie")


def test_unregister_busy_adapter_is_loss_free():
    """Retiring an adapter with a request in flight: the request keeps
    its full token budget; the copies leave only after it finishes."""
    cluster, adapters = make_sim_cluster()
    cluster.track_tokens = True
    cluster.start()
    aid = adapters[0].adapter_id
    req = ServeRequest(req_id=7, adapter_id=aid, prompt_len=16,
                       output_len=24, arrival=0.0)
    cluster.submit(req, 0.0)
    evs = cluster.poll(0.0)
    cluster.unregister_adapter(aid)
    assert cluster._retiring == {aid}     # busy: retire is pending
    evs += cluster.drain()
    toks = sum(len(e.tokens) for e in evs if e.req.req_id == 7)
    assert toks == 24                     # zero lost tokens
    assert not cluster._retiring
    assert aid not in cluster.meta
    assert cluster.report().unregistered == 1


# ---------------------------------------------------------------------
# report safety (satellite: mid-flight percentiles + snapshot())
# ---------------------------------------------------------------------
def test_report_percentiles_safe_on_empty_window():
    rep = ClusterReport(results=[], summary={}, rebalances=0,
                        placements=[], per_server_counts=[], timed_out=0,
                        fetches=0, fetch_bytes=0,
                        max_adapters_per_server=0, total_adapter_bytes=0,
                        memory_profile=[])
    assert math.isnan(rep.p50_ttft()) and math.isnan(rep.p95_ttft())
    assert rep.mean_tbt() == 0.0 and rep.p95_tbt() == 0.0
    assert rep.completed() == 0
    assert not rep.meets_slo(1.0)         # no data is not "meeting SLO"
    assert rep.slo_attainment(1.0) == 1.0


def test_snapshot_mid_flight():
    """snapshot() works with requests still in progress — nothing
    raises, unfinished requests are visible, percentiles only cover
    finished ones."""
    cluster, adapters = make_sim_cluster()
    cluster.start()
    for i in range(4):
        cluster.submit(ServeRequest(
            req_id=i, adapter_id=adapters[i % len(adapters)].adapter_id,
            prompt_len=16, output_len=50, arrival=0.0), 0.0)
    cluster.poll(0.0)                     # nothing finished yet
    snap = cluster.snapshot()
    assert snap.in_progress == 4 and snap.completed() == 0
    assert math.isnan(snap.p95_ttft())    # no raise on partial window
    cluster.drain()
    final = cluster.snapshot()
    assert final.in_progress == 0 and final.completed() == 4
    assert final.p95_ttft() > 0


# ---------------------------------------------------------------------
# gateway over SimBackend
# ---------------------------------------------------------------------
def test_gateway_sse_ordering_and_health():
    cluster, adapters = make_sim_cluster()
    with GatewayHarness(cluster) as h:
        status, health, _ = http_json(h.port, "GET", "/healthz")
        assert status == 200 and health["status"] == "ok"

        status, chunks = sse_request(h.port, {
            "adapter_id": adapters[0].adapter_id,
            "prompt_len": 16, "max_tokens": 10})
        assert status == 200
        # strictly ordered, gapless chunk indices; exact token budget
        seen = 0
        for c in chunks:
            assert c["index"] == seen
            seen += len(c["tokens"])
        assert seen == 10
        assert chunks[-1]["finish_reason"] == "stop"
        assert chunks[-1]["usage"]["completion_tokens"] == 10

        status, m, _ = http_json(h.port, "GET", "/metrics")
        assert status == 200
        assert "repro_gateway_streamed_tokens_total 10" in m
        assert "repro_cluster_completed_total 1" in m
    assert h.gw.final_report.completed() == 1


def test_gateway_unknown_adapter_404():
    cluster, _ = make_sim_cluster()
    with GatewayHarness(cluster) as h:
        status, body, _ = http_json(h.port, "POST", "/v1/completions",
                                    {"adapter_id": "ghost",
                                     "prompt_len": 8})
        assert status == 404 and "ghost" in body["error"]
        status, _, _ = http_json(h.port, "GET", "/nope")
        assert status == 404
        status, body, _ = http_json(h.port, "POST", "/v1/completions",
                                    {"prompt_len": 8})
        assert status == 400              # no adapter_id at all


def test_gateway_runtime_adapter_lifecycle():
    """register -> route -> complete -> delete over HTTP, with the
    adapter table reflecting every step."""
    cluster, _ = make_sim_cluster()
    with GatewayHarness(cluster) as h:
        status, created, _ = http_json(h.port, "POST", "/v1/adapters",
                                       {"adapter_id": "live", "rank": 16,
                                        "nbytes": 4 << 20})
        assert status == 201 and created["server"] in (0, 1)
        # duplicate register conflicts
        status, _, _ = http_json(h.port, "POST", "/v1/adapters",
                                 {"adapter_id": "live", "rank": 16})
        assert status == 409

        status, table, _ = http_json(h.port, "GET", "/v1/adapters")
        entry = {e["adapter_id"]: e for e in table["adapters"]}["live"]
        assert entry["rank"] == 16 and not entry["draining"]
        assert str(created["server"]) in {str(s) for s in
                                          entry["servers"]}

        status, chunks = sse_request(h.port, {"adapter_id": "live",
                                              "prompt_len": 8,
                                              "max_tokens": 5})
        assert status == 200 and len(tokens_of(chunks)) == 5

        status, body, _ = http_json(h.port, "DELETE",
                                    "/v1/adapters/live")
        assert status == 202 and body["draining"]
        status, body, _ = http_json(h.port, "POST", "/v1/completions",
                                    {"adapter_id": "live",
                                     "prompt_len": 8})
        assert status == 404              # retiring: routing is closed
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            _, table, _ = http_json(h.port, "GET", "/v1/adapters")
            if all(e["adapter_id"] != "live"
                   for e in table["adapters"]):
                break
            time.sleep(0.02)
        else:
            pytest.fail("retired adapter never left the table")
        status, _, _ = http_json(h.port, "DELETE", "/v1/adapters/live")
        assert status == 404
    rep = h.gw.final_report
    assert rep.registered == 1 and rep.unregistered == 1


def test_gateway_admission_fairness_429():
    """A greedy tenant saturating its inflight cap gets 429 +
    Retry-After while another tenant keeps admitting."""
    cluster, adapters = make_sim_cluster()
    admission = AdmissionController(max_inflight=1)
    with GatewayHarness(cluster, admission=admission) as h:
        aid = adapters[0].adapter_id
        got_tokens = threading.Event()
        result = {}

        def greedy_stream():
            conn = http.client.HTTPConnection("127.0.0.1", h.port,
                                              timeout=300)
            conn.request("POST", "/v1/completions",
                         json.dumps({"adapter_id": aid,
                                     "prompt_len": 16,
                                     "max_tokens": 400}),
                         {"Content-Type": "application/json",
                          "x-tenant": "greedy"})
            resp = conn.getresponse()
            result["status"] = resp.status
            n = 0
            while True:
                line = resp.fp.readline()
                if not line:
                    break
                line = line.decode().strip()
                if not line.startswith("data: "):
                    continue
                if line == "data: [DONE]":
                    break
                obj = json.loads(line[6:])
                n += len(obj.get("tokens") or [])
                if n:
                    got_tokens.set()
            result["tokens"] = n
            conn.close()

        t = threading.Thread(target=greedy_stream, daemon=True)
        t.start()
        assert got_tokens.wait(60), "greedy stream never started"

        # greedy's second request: over its cap -> 429 + Retry-After
        status, body, headers = http_json(
            h.port, "POST", "/v1/completions",
            {"adapter_id": aid, "prompt_len": 8, "max_tokens": 2,
             "stream": False}, headers={"x-tenant": "greedy"})
        assert status == 429
        assert float(headers["retry-after"]) > 0
        assert "max-inflight" in body["error"]

        # a polite tenant admits just fine at the same instant
        status, body, _ = http_json(
            h.port, "POST", "/v1/completions",
            {"adapter_id": aid, "prompt_len": 8, "max_tokens": 2,
             "stream": False}, headers={"x-tenant": "polite"})
        assert status == 200 and len(body["tokens"]) == 2

        t.join(300)
        assert result["tokens"] == 400    # greedy still completes
        assert admission.rejected.get("greedy", 0) >= 1
        assert "polite" not in admission.rejected


def test_gateway_sigterm_drain_zero_lost_tokens_sim():
    """SIGTERM (begin_shutdown — the handler the signal invokes) while
    streams are mid-flight: every open stream still delivers its full
    token budget, new work is refused, and the gateway exits clean."""
    cluster, adapters = make_sim_cluster()
    h = GatewayHarness(cluster)
    with h:
        budgets = [60, 80, 100, 120]
        results = [None] * len(budgets)

        def stream(i):
            status, chunks = sse_request(h.port, {
                "adapter_id": adapters[i % len(adapters)].adapter_id,
                "prompt_len": 16, "max_tokens": budgets[i]})
            results[i] = (status, len(tokens_of(chunks)),
                          chunks[-1].get("finish_reason")
                          if chunks else None)

        threads = [threading.Thread(target=stream, args=(i,),
                                    daemon=True)
                   for i in range(len(budgets))]
        for t in threads:
            t.start()
        # wait until all four are actually in flight, then pull the plug
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline and cluster.pending() < 4:
            time.sleep(0.005)
        assert cluster.pending() == 4
        h.loop.call_soon_threadsafe(h.gw.begin_shutdown)

        # draining: new completions are refused...
        status, _, _ = http_json(h.port, "POST", "/v1/completions",
                                 {"adapter_id":
                                  adapters[0].adapter_id,
                                  "prompt_len": 8})
        assert status == 503
        for t in threads:
            t.join(300)
    # ...but every in-flight stream finished with zero lost tokens
    for (status, n, reason), budget in zip(results, budgets):
        assert status == 200 and n == budget and reason == "stop"
    rep = h.gw.final_report
    assert rep.completed() == len(budgets) and rep.timed_out == 0
    assert h.gw.state == "stopped"


# ---------------------------------------------------------------------
# gateway over the real JAX engine
# ---------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine_cluster(cfg, params, adapters, n_servers=2, max_len=40):
    be = EngineBackend(cfg, params, n_servers, max_batch=2,
                       max_len=max_len, seed=0)
    return LoRAServeCluster(be, adapters, network=NetworkModel(),
                            rebalance_period=1e9, seed=0)


def test_engine_e2e_register_stream_parity_busy_delete(setup):
    """The acceptance path on the real engine: register a new adapter
    over HTTP, stream a completion via SSE token-identical to the batch
    ``run()`` path, then DELETE a busy adapter mid-stream and observe a
    loss-free drain."""
    cfg, params = setup
    rng = random.Random(11)
    prompt = [rng.randrange(1, cfg.vocab_size) for _ in range(6)]
    base = [AdapterInfo("base-r8", 8, nbytes=8 << 20),
            AdapterInfo("busy-r16", 16, nbytes=16 << 20)]
    hot = AdapterInfo("hot-r8", 8, nbytes=8 << 20)

    # batch reference: same seed, "hot-r8" present from t=0. Bank
    # weights are keyed per adapter id, so a runtime registration must
    # produce bit-identical weights — and therefore identical tokens.
    ref_req = ServeRequest(req_id=0, adapter_id="hot-r8", rank=8,
                           prompt_len=len(prompt), output_len=6,
                           prompt=list(prompt), arrival=0.0)
    _engine_cluster(cfg, params, base + [hot]).run([ref_req])
    ref_tokens = list(ref_req.output)
    assert len(ref_tokens) == 6

    cluster = _engine_cluster(cfg, params, base)
    with GatewayHarness(cluster) as h:
        status, created, _ = http_json(h.port, "POST", "/v1/adapters",
                                       {"adapter_id": "hot-r8",
                                        "rank": 8, "nbytes": 8 << 20})
        assert status == 201

        status, chunks = sse_request(h.port, {"adapter_id": "hot-r8",
                                              "prompt": prompt,
                                              "max_tokens": 6})
        assert status == 200
        seen = 0
        for c in chunks:                  # ordered, gapless on the
            assert c["index"] == seen     # real engine too
            seen += len(c["tokens"])
        assert tokens_of(chunks) == ref_tokens

        # DELETE an adapter while its stream is mid-flight
        first_token = threading.Event()
        result = {}

        def busy_stream():
            conn = http.client.HTTPConnection("127.0.0.1", h.port,
                                              timeout=600)
            conn.request("POST", "/v1/completions",
                         json.dumps({"adapter_id": "busy-r16",
                                     "prompt": prompt,
                                     "max_tokens": 24}),
                         {"Content-Type": "application/json"})
            resp = conn.getresponse()
            result["status"] = resp.status
            toks = []
            while True:
                line = resp.fp.readline()
                if not line:
                    break
                line = line.decode().strip()
                if line == "data: [DONE]":
                    break
                if not line.startswith("data: "):
                    continue
                obj = json.loads(line[6:])
                toks.extend(obj.get("tokens") or [])
                if toks:
                    first_token.set()
            result["tokens"] = toks
            conn.close()

        t = threading.Thread(target=busy_stream, daemon=True)
        t.start()
        assert first_token.wait(300), "busy stream never started"
        status, body, _ = http_json(h.port, "DELETE",
                                    "/v1/adapters/busy-r16")
        assert status == 202 and body["draining"]
        t.join(600)
        # the in-flight request survived the retire with its full budget
        assert result["status"] == 200 and len(result["tokens"]) == 24
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            _, table, _ = http_json(h.port, "GET", "/v1/adapters")
            if all(e["adapter_id"] != "busy-r16"
                   for e in table["adapters"]):
                break
            time.sleep(0.05)
        else:
            pytest.fail("retired adapter never left the table")
    rep = h.gw.final_report
    assert rep.completed() == 2 and rep.timed_out == 0
    assert rep.registered == 1 and rep.unregistered == 1


def test_gateway_sigterm_drain_zero_lost_tokens_engine(setup):
    cfg, params = setup
    adapters = [AdapterInfo("ea-r8", 8, nbytes=8 << 20),
                AdapterInfo("eb-r16", 16, nbytes=16 << 20)]
    cluster = _engine_cluster(cfg, params, adapters)
    rng = random.Random(2)
    prompts = [[rng.randrange(1, cfg.vocab_size) for _ in range(6)]
               for _ in range(2)]
    budgets = [14, 18]
    results = [None, None]
    h = GatewayHarness(cluster)
    with h:
        def stream(i):
            status, chunks = sse_request(h.port, {
                "adapter_id": adapters[i].adapter_id,
                "prompt": prompts[i], "max_tokens": budgets[i]})
            results[i] = (status, len(tokens_of(chunks)))

        threads = [threading.Thread(target=stream, args=(i,),
                                    daemon=True) for i in range(2)]
        for t in threads:
            t.start()
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline and cluster.pending() < 2:
            time.sleep(0.01)
        assert cluster.pending() == 2
        h.loop.call_soon_threadsafe(h.gw.begin_shutdown)
        status, _, _ = http_json(h.port, "POST", "/v1/completions",
                                 {"adapter_id": "ea-r8",
                                  "prompt_len": 4})
        assert status == 503
        for t in threads:
            t.join(600)
    for (status, n), budget in zip(results, budgets):
        assert status == 200 and n == budget
    assert h.gw.final_report.completed() == 2
    assert h.gw.state == "stopped"


def test_launch_server_real_sigterm_subprocess():
    """The actual signal path: spawn ``python -m repro.launch.server``,
    deliver a real SIGTERM, expect a clean drain and exit code 0."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.server", "--backend",
         "sim", "--port", "0", "--servers", "2", "--adapters", "4"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    try:
        line = proc.stdout.readline().strip()
        assert line.startswith("listening on "), line
        host, port = line.split()[-1].rsplit(":", 1)
        status, chunks = sse_request(int(port), {
            "adapter_id": "ad0-r8", "prompt_len": 8, "max_tokens": 4})
        assert status == 200 and len(tokens_of(chunks)) == 4
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, out
    assert "gateway drained OK" in out
    assert "served=1" in out
