"""Feature-level model tests: MLA absorption, sliding-window ring cache,
LoRA bank semantics inside the model, merge equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.lora.adapter import init_adapter, init_bank, merge_adapter
from repro.models import model as M


def test_mla_absorbed_matches_naive():
    cfg = get_smoke_config("deepseek-v2-lite-16b")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    _, cache = M.prefill(cfg, params, tokens[:, :S], cache_len=S + 2)
    l_naive, _ = M.decode_step(cfg, params, cache, tokens[:, S],
                               mla_absorbed=False)
    l_abs, _ = M.decode_step(cfg, params, cache, tokens[:, S],
                             mla_absorbed=True)
    np.testing.assert_allclose(np.asarray(l_naive), np.asarray(l_abs),
                               atol=1e-3)


def test_sliding_window_matches_full_within_window():
    cfg_w = get_smoke_config("stablelm-1.6b").with_sliding_window(8)
    cfg_f = get_smoke_config("stablelm-1.6b")
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg_f, key)
    B = 2
    toks = jax.random.randint(key, (B, 24), 0, cfg_f.vocab_size)
    _, cw = M.prefill(cfg_w, params, toks[:, :4], cache_len=8)
    _, cf = M.prefill(cfg_f, params, toks[:, :4], cache_len=32)
    diverged = False
    for t in range(4, 16):
        lw, cw = M.decode_step(cfg_w, params, cw, toks[:, t])
        lf, cf = M.decode_step(cfg_f, params, cf, toks[:, t])
        d = float(jnp.max(jnp.abs(lw - lf)))
        assert not bool(jnp.isnan(lw).any())
        if t < 8:
            assert d < 1e-3, f"in-window mismatch at {t}: {d}"
        elif d > 1e-3:
            diverged = True
    assert diverged, "window never truncated context"


def test_lora_bank_changes_output_per_adapter():
    cfg = get_smoke_config("llama-7b-paper")
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    bank = init_bank(cfg, [8, 64], key)
    # randomize B matrices so adapters actually differ
    bank = jax.tree.map(
        lambda t: jax.random.normal(key, t.shape) * 0.1, bank)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h0, _ = M.forward(cfg, params, tokens, bank=bank,
                      lora_idx=jnp.array([0, 0]))
    h1, _ = M.forward(cfg, params, tokens, bank=bank,
                      lora_idx=jnp.array([1, 1]))
    hb, _ = M.forward(cfg, params, tokens, bank=bank,
                      lora_idx=jnp.array([0, 1]))
    assert float(jnp.max(jnp.abs(h0 - h1))) > 1e-4
    # mixed batch row 0 follows adapter 0, row 1 follows adapter 1
    np.testing.assert_allclose(np.asarray(hb[0]), np.asarray(h0[0]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hb[1]), np.asarray(h1[1]),
                               atol=1e-4)


def test_merge_adapter_equals_lora_path():
    """Paper §II-B: merging an adapter into the base weights must equal
    applying it through the batched path (scaling 1)."""
    cfg = get_smoke_config("llama-7b-paper")
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key)
    adapter = init_adapter(cfg, 8, key)
    adapter = jax.tree.map(
        lambda t: jax.random.normal(jax.random.PRNGKey(9), t.shape) * 0.05,
        adapter)
    bank = jax.tree.map(lambda t: t[:, None], adapter)
    B, S = 2, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h_lora, _ = M.forward(cfg, params, tokens, bank=bank,
                          lora_idx=jnp.zeros((B,), jnp.int32))
    merged = merge_adapter(params, adapter, cfg)
    h_merged, _ = M.forward(cfg, merged, tokens)
    np.testing.assert_allclose(np.asarray(h_lora), np.asarray(h_merged),
                               atol=2e-3)


def test_rwkv_decode_state_is_constant_size():
    cfg = get_smoke_config("rwkv6-7b")
    key = jax.random.PRNGKey(4)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 6), 0, cfg.vocab_size)
    _, cache = M.prefill(cfg, params, toks, cache_len=6)
    assert "k" not in cache        # no KV cache at all
    sizes = {k: v.size for k, v in cache.items()}
    _, cache2 = M.decode_step(cfg, params, cache,
                              jnp.zeros((1,), jnp.int32))
    assert {k: v.size for k, v in cache2.items()} == sizes


def test_kv_regroup_identity():
    """§Perf iter 4 transform: duplicating kv heads + zero-padding query
    groups is numerically the identity for grouped-query attention."""
    import jax.numpy as jnp
    from repro.models.attention import (_pad_regroup_q, _regroup_plan,
                                        _unpad_o)
    from repro.models.common import flash_attention

    key = jax.random.PRNGKey(0)
    B, S, H, Kv, hd = 2, 32, 10, 2, 16        # G=5, like qwen's 40/8
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Kv, hd))
    pos = jnp.arange(S)
    base = flash_attention(q, k, v, causal=True, q_positions=pos,
                           k_positions=pos, chunk_q=16, chunk_k=16)
    plan = _regroup_plan(H, Kv, n=4)           # Kv=2 -> rep=2, Gp=3
    assert plan == (2, 3)
    rep, Gp = plan
    qf = _pad_regroup_q(q, Kv, rep, Gp)
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    o = flash_attention(qf, kf, vf, causal=True, q_positions=pos,
                        k_positions=pos, chunk_q=16, chunk_k=16,
                        scale=1.0 / (hd ** 0.5))
    out = _unpad_o(o, Kv, H // Kv, rep, Gp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               atol=1e-5, rtol=1e-5)
