"""Token-compacting ``sgmv_rank_bucketed`` vs the pure-jnp oracle:
mixed-rank batches, compact (per-bucket) banks, the decode case
(block_t=1), and the single-bucket degenerate case."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import sgmv, sgmv_rank_bucketed, sgmv_reference


def _mixed_setup(seed=3, T=29, d=128, do=256, r_small=8, r_big=64):
    """3 adapters in 2 buckets; returns both the full padded bank and the
    per-bucket compact banks holding the same weights."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (T, d))
    A8 = jax.random.normal(ks[1], (2, d, r_small)) * 0.1
    B8 = jax.random.normal(ks[2], (2, r_small, do)) * 0.1
    A64 = jax.random.normal(ks[3], (1, d, r_big)) * 0.1
    B64 = jax.random.normal(ks[4], (1, r_big, do)) * 0.1
    # padded bank: adapters 0,2 are the rank-8 pair, adapter 1 is rank-64
    Apad = jnp.stack([
        jnp.pad(A8[0], ((0, 0), (0, r_big - r_small))), A64[0],
        jnp.pad(A8[1], ((0, 0), (0, r_big - r_small)))])
    Bpad = jnp.stack([
        jnp.pad(B8[0], ((0, r_big - r_small), (0, 0))), B64[0],
        jnp.pad(B8[1], ((0, r_big - r_small), (0, 0)))])
    aid = jax.random.randint(ks[5], (T,), 0, 3)
    bucket = jnp.array([0, 1, 0], jnp.int32)
    local = jnp.array([0, 0, 1], jnp.int32)
    return x, [(A8, B8), (A64, B64)], (Apad, Bpad), aid, bucket, local


@pytest.mark.parametrize("block_t", [16, 8, 1])   # 1 == decode (BGMV)
def test_bucketed_compact_banks_match_reference(block_t):
    x, banks, (Apad, Bpad), aid, bucket, local = _mixed_setup()
    y_b = sgmv_rank_bucketed(x, banks, aid, bucket, adapter_local=local,
                             block_t=block_t, interpret=True)
    y_r = sgmv_reference(x, Apad, Bpad, aid)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_r), atol=1e-4)


def test_bucketed_full_banks_match_reference():
    """adapter_local=None: every bucket bank indexed by the global
    adapter id (the pre-refactor layout) still works."""
    key = jax.random.PRNGKey(2)
    A8 = jax.random.normal(key, (3, 128, 8)) * 0.1
    B8 = jax.random.normal(key, (3, 8, 256)) * 0.1
    A64 = jax.random.normal(key, (3, 128, 64)) * 0.1
    B64 = jax.random.normal(key, (3, 64, 256)) * 0.1
    bucket = jnp.array([0, 1, 0])
    Apad = jnp.where(bucket[:, None, None] == 0,
                     jnp.pad(A8, ((0, 0), (0, 0), (0, 56))), A64)
    Bpad = jnp.where(bucket[:, None, None] == 0,
                     jnp.pad(B8, ((0, 0), (0, 56), (0, 0))), B64)
    x = jax.random.normal(key, (24, 128))
    aid = jax.random.randint(key, (24,), 0, 3)
    y_b = sgmv_rank_bucketed(x, [(A8, B8), (A64, B64)], aid, bucket,
                             interpret=True)
    y_r = sgmv_reference(x, Apad, Bpad, aid)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_r), atol=1e-4)


def test_single_bucket_degenerates_to_sgmv():
    """One bucket == plain SGMV on the same bank (no splitting overhead
    in the math)."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (17, 64))
    A = jax.random.normal(ks[1], (2, 64, 16)) * 0.1
    B = jax.random.normal(ks[2], (2, 16, 128)) * 0.1
    aid = jax.random.randint(ks[3], (17,), 0, 2)
    bucket = jnp.zeros((2,), jnp.int32)
    local = jnp.arange(2, dtype=jnp.int32)
    y_b = sgmv_rank_bucketed(x, [(A, B)], aid, bucket,
                             adapter_local=local, interpret=True)
    y_s = sgmv(x, A, B, aid, interpret=True)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_s), atol=1e-5)


def test_empty_bucket_is_skipped():
    """A bucket with no tokens in the batch contributes nothing (and the
    kernel for it never launches)."""
    x, banks, (Apad, Bpad), _, bucket, local = _mixed_setup()
    aid = jnp.full((x.shape[0],), 1, jnp.int32)   # only the rank-64 one
    y_b = sgmv_rank_bucketed(x, banks, aid, bucket, adapter_local=local,
                             interpret=True)
    y_r = sgmv_reference(x, Apad, Bpad, aid)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_r), atol=1e-4)


def test_scaling_applied_bucketed():
    x, banks, _, aid, bucket, local = _mixed_setup()
    y1 = sgmv_rank_bucketed(x, banks, aid, bucket, adapter_local=local,
                            scaling=2.0, interpret=True)
    y2 = sgmv_rank_bucketed(x, banks, aid, bucket, adapter_local=local,
                            scaling=1.0, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), 2 * np.asarray(y2),
                               rtol=1e-5)
