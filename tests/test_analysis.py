"""repro.analysis: lint-rule fixtures (must / must-not trigger), the
static VMEM checker against inflated scratch, and the protocol model
checker re-finding the PR 3 GC-vs-fetch race when the ``_gc`` in-flight
guard is disabled."""
import json
import os
import textwrap

from repro.analysis import (ALL_RULES, Finding, Severity, has_errors,
                            suppressions)
from repro.analysis import protocol, vmem
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.linter import lint_source, lint_tree

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _rules(src):
    return [f.rule for f in lint_source(textwrap.dedent(src))]


# --------------------------------------------------------------------------
# linter: each rule has a fixture that must trigger and one that must not
# --------------------------------------------------------------------------


def test_host_sync_in_jit():
    assert "host-sync" in _rules("""
        @jax.jit
        def f(x):
            return x.item()
        """)


def test_host_sync_via_partial_jit_and_asarray():
    assert "host-sync" in _rules("""
        @functools.partial(jax.jit, static_argnums=0)
        def f(k, x):
            y = np.asarray(x)
            return y
        """)


def test_host_sync_in_decode_path_method():
    assert "host-sync" in _rules("""
        class Engine:
            def _decode_once(self, x):
                return float(np.asarray(x)[0])
        """)


def test_no_host_sync_outside_hot_regions():
    assert _rules("""
        def summarize(x):
            return x.item()
        """) == []


def test_host_sync_loop_per_element():
    assert "host-sync-loop" in _rules("""
        def step(batch):
            toks = jnp.argmax(batch, axis=-1)
            out = []
            for i in range(4):
                out.append(int(toks[i]))
            return out
        """)


def test_host_sync_loop_quiet_after_materialize():
    assert _rules("""
        def step(batch):
            toks = jnp.argmax(batch, axis=-1)
            toks_np = np.asarray(toks)
            out = []
            for i in range(4):
                out.append(int(toks_np[i]))
            return out
        """) == []


def test_traced_if_on_jnp_value():
    assert "traced-if" in _rules("""
        @jax.jit
        def g(x):
            m = jnp.max(x)
            if m > 0:
                return x
            return -x
        """)


def test_if_on_static_python_value_ok():
    assert _rules("""
        @jax.jit
        def g(x, n_blocks):
            if n_blocks > 4:
                return x * 2
            return x
        """) == []


def test_raw_pallas_call_without_interpret_resolution():
    assert "raw-pallas-call" in _rules("""
        def launch(x):
            return pl.pallas_call(kernel, out_shape=x)(x)
        """)


def test_pallas_call_with_resolve_interpret_ok():
    assert "raw-pallas-call" not in _rules("""
        def launch(x, interpret=None):
            interpret = resolve_interpret(interpret)
            return pl.pallas_call(kernel, out_shape=x,
                                  interpret=interpret)(x)
        """)


def test_mutable_default():
    assert "mutable-default" in _rules("""
        def f(a, acc=[]):
            acc.append(a)
            return acc
        """)
    assert _rules("""
        def f(a, acc=None):
            return (acc or []) + [a]
        """) == []


def test_shared_mutable_class_attr():
    assert "shared-mutable-class-attr" in _rules("""
        class Cache:
            entries = {}
        """)
    assert _rules("""
        class Cache:
            __slots__ = ("entries",)
            LIMIT = 4
            def __init__(self):
                self.entries = {}
        """) == []


def test_shared_mutable_dataclass_field():
    assert "shared-mutable-dataclass" in _rules("""
        @dataclasses.dataclass
        class Cfg:
            xs: List[int] = dataclasses.field(default=[])
        """)
    assert "shared-mutable-dataclass" in _rules("""
        @dataclasses.dataclass
        class Cfg:
            xs: list = []
        """)
    assert _rules("""
        @dataclasses.dataclass
        class Cfg:
            xs: List[int] = dataclasses.field(default_factory=list)
        """) == []


def test_side_effect_cond_statement():
    assert "side-effect-cond" in _rules("""
        def f(x, log):
            log(x) if x else None
        """)
    assert _rules("""
        def f(x, log):
            y = log(x) if x else None
            return y
        """) == []


# --------------------------------------------------------------------------
# suppressions
# --------------------------------------------------------------------------


def test_async_blocking_call_in_handler():
    assert "async-blocking" in _rules("""
        class Gateway:
            async def _handle(self, req):
                time.sleep(0.1)
                return req
        """)


def test_async_blocking_subprocess_and_urlopen():
    rules = _rules("""
        async def fetch(url):
            subprocess.run(["curl", url])
            return urllib.request.urlopen(url)
        """)
    assert rules.count("async-blocking") == 2


def test_sync_code_may_block_and_awaited_sleep_ok():
    assert _rules("""
        def warmup():
            time.sleep(0.1)

        async def pump(self):
            await asyncio.sleep(0.1)
        """) == []


def test_nested_sync_fn_inside_async_not_flagged():
    # the blocking call's *innermost* enclosing function is synchronous:
    # it runs off-loop (e.g. via run_in_executor), so it may block
    assert _rules("""
        async def handler(req):
            def worker():
                time.sleep(1.0)
            return worker
        """) == []


def test_raw_log_print_and_logging_calls():
    rules = _rules("""
        def route(self, req):
            print("routing", req)
            logging.info("routed %s", req)
            logger.debug("detail")
            return req
        """)
    assert rules.count("raw-log") == 3


def test_raw_log_exempt_in_launch_cli():
    src = textwrap.dedent("""
        def main():
            print("served OK")
        """)
    assert [f.rule for f in lint_source(
        src, path="src/repro/launch/serve.py")] == []
    assert "raw-log" in [f.rule for f in lint_source(
        src, path="src/repro/serving/cluster.py")]


def test_raw_log_suppression_marker():
    assert _rules("""
        def dump(self):
            print("state")  # analysis: ignore[raw-log] debug escape hatch
        """) == []


def test_raw_log_quiet_on_unrelated_calls():
    # method named .info()/.log() on a non-logger object must not trip
    assert _rules("""
        def snapshot(self):
            self.hub.observe_completion(req, now)
            return math.log(2.0)
        """) == []


def test_suppression_same_line_and_line_above():
    assert _rules("""
        @jax.jit
        def f(x):
            return x.item()  # analysis: ignore[host-sync] sync by design
        """) == []
    assert _rules("""
        @jax.jit
        def f(x):
            # analysis: ignore[host-sync] the one sanctioned sync point
            return x.item()
        """) == []


def test_suppression_is_rule_scoped():
    # an ignore for a different rule must not silence host-sync
    assert "host-sync" in _rules("""
        @jax.jit
        def f(x):
            return x.item()  # analysis: ignore[traced-if]
        """)
    # a bare marker silences everything on the line
    assert _rules("""
        @jax.jit
        def f(x):
            return x.item()  # analysis: ignore
        """) == []


def test_suppressions_parser():
    supp = suppressions("a = 1  # analysis: ignore[r1, r2]\n"
                        "# analysis: ignore\nb = 2\n")
    assert supp[1] == {"r1", "r2"}
    assert ALL_RULES in supp[2] and ALL_RULES in supp[3]


def test_repo_tree_is_lint_clean():
    findings = lint_tree(os.path.join(SRC, "repro"))
    assert findings == [], "\n".join(f.format() for f in findings)


# --------------------------------------------------------------------------
# vmem: static budget check on the real kernels
# --------------------------------------------------------------------------


def _sgmv_source():
    with open(os.path.join(SRC, "repro", "kernels", "sgmv.py")) as f:
        return f.read()


def test_vmem_bf16_envelope_fits():
    src = _sgmv_source()
    envs = vmem.kernel_envs(SRC, itemsize=2)
    findings = vmem.analyze_source(src, "sgmv.py", envs,
                                   vmem.vmem_budget(SRC))
    assert findings == [], "\n".join(f.format() for f in findings)


def test_vmem_fails_on_inflated_scratch():
    src = _sgmv_source()
    needle = "pltpu.VMEM((block_t, r), x_pad.dtype)"
    assert needle in src, "fused-kernel scratch line moved; update test"
    bad = src.replace(needle,
                      "pltpu.VMEM((block_t, r * 4096), x_pad.dtype)")
    envs = vmem.kernel_envs(SRC, itemsize=2)
    findings = vmem.analyze_source(bad, "sgmv.py", envs,
                                   vmem.vmem_budget(SRC))
    assert any(f.rule == "vmem-budget" and
               "sgmv_fused_blocks" in f.message for f in findings)
    assert has_errors(findings)


def test_vmem_fails_under_tiny_budget():
    src = _sgmv_source()
    envs = vmem.kernel_envs(SRC, itemsize=2)
    findings = vmem.analyze_source(src, "sgmv.py", envs, budget=1 << 20)
    assert any(f.rule == "vmem-budget" for f in findings)


def test_vmem_full_pass_warns_only_on_fp32_headroom():
    findings = vmem.analyze_kernels(SRC)
    assert not has_errors(findings)
    assert all(f.rule == "vmem-headroom" for f in findings)


# --------------------------------------------------------------------------
# protocol: exhaustive suite on the real store; race re-found on a
# store with the _gc in-flight guard disabled
# --------------------------------------------------------------------------


def test_protocol_small_models_pass_exhaustively():
    # crash-recovery is depth-bounded by design: its fault alphabet
    # (stall -> timeout -> retry) keeps minting fresh attempt counters,
    # so it has no finite fixpoint to reach
    bounded = {"crash-recovery"}
    for name, res in protocol.small_model_suite():
        assert res.ok, (name, res.violations[:3])
        if name not in bounded:
            assert not res.truncated, f"{name} did not reach its fixpoint"
        assert res.states > 50, f"{name} explored suspiciously few states"


def test_protocol_refinds_pr3_gc_race_without_guard():
    from repro.core.pool import AdapterStore

    class Unguarded(AdapterStore):
        """The pre-fix _gc: evicts without consulting in-flight plans
        (simulates removing the guard in core/pool.py)."""

        def _gc(self, adapter_id):
            inflight, self._inflight = self._inflight, {}
            try:
                super()._gc(adapter_id)
            finally:
                self._inflight = inflight

    res = protocol.check_model(
        protocol.fetch_gc_model(store_cls=Unguarded, max_depth=5))
    races = [v for v in res.violations
             if v.invariant == "inflight-src-resident"]
    assert races, "checker failed to re-find the GC-vs-fetch race"
    assert any("GC-vs-fetch race" in v.message for v in races)
    # the minimal counterexample is a real 4-action interleaving
    assert min(len(v.trace) for v in races) <= 5


def test_store_invariants_flag_manufactured_corruption():
    w = protocol.World(protocol.fetch_gc_model())
    assert w.invariant_errors() == []
    w.store.local[0].discard("a0")          # index now lies
    errs = w.invariant_errors()
    assert any(e.startswith("index-consistent") for e in errs)


def test_runtime_hook_env_gate(monkeypatch):
    from repro.core.pool import runtime_checks_enabled
    monkeypatch.delenv("REPRO_CHECK_INVARIANTS", raising=False)
    assert not runtime_checks_enabled()
    monkeypatch.setenv("REPRO_CHECK_INVARIANTS", "1")
    assert runtime_checks_enabled()
    w = protocol.World(protocol.fetch_gc_model())
    assert w.store.check_invariants(now=0.0) == []


# --------------------------------------------------------------------------
# CLI: exit codes + report artifact
# --------------------------------------------------------------------------


def test_cli_lint_clean_tree_exits_zero(capsys):
    assert analysis_main(["--passes=lint", "--root", SRC]) == 0


def test_cli_exits_nonzero_on_seeded_fixture(tmp_path, capsys):
    pkg = tmp_path / "repro"
    pkg.mkdir()
    (pkg / "bad.py").write_text("def f(a, acc=[]):\n    return acc\n")
    report = tmp_path / "findings.json"
    rc = analysis_main(["--passes=lint", "--root", str(tmp_path),
                        "--report", str(report), "--format=github"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "::error" in out and "mutable-default" in out
    data = json.loads(report.read_text())
    assert data and data[0]["rule"] == "mutable-default"


def test_cli_rejects_unknown_pass():
    assert analysis_main(["--passes=nope"]) == 2


def test_finding_github_format():
    f = Finding("a.py", 3, "r", "msg", Severity.WARNING, col=7)
    assert f.format("github") == \
        "::warning file=a.py,line=3,col=7,title=r::msg"
    assert f.format() == "a.py:3:7: [r] msg"
