"""Quickstart: serve a small multi-LoRA model on one engine.

Loads the reduced Llama-7B-family config, creates a 4-adapter bank with
heterogeneous ranks (8..128), submits a handful of requests through the
continuous-batching engine, and prints TTFT/TBT metrics — the minimal
single-server slice of the paper's stack.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving import Request, ServingEngine


def main():
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    adapters = {"support-bot": 8, "code-assist": 32,
                "summarizer": 64, "legal-redline": 128}
    engine = ServingEngine(cfg, params, adapters, max_batch=4, max_len=64)
    print(f"engine up: {len(adapters)} adapters, bank max rank "
          f"{engine.max_rank} (every co-batched request pays it)")

    now = time.monotonic()
    prompts = [
        ("support-bot", [12, 45, 88, 21, 9, 4]),
        ("legal-redline", [7, 3, 99, 150, 31, 18, 42]),
        ("code-assist", [5, 5, 23, 77]),
        ("summarizer", [61, 2, 19, 240, 11]),
        ("support-bot", [90, 14, 3]),
    ]
    for i, (aid, prompt) in enumerate(prompts):
        engine.submit(Request(i, aid, prompt, max_new_tokens=8,
                              arrival=now))
    summary = engine.run_until_drained()
    print("metrics:", {k: round(v, 4) if isinstance(v, float) else v
                       for k, v in summary.items()})


if __name__ == "__main__":
    main()
