"""Streaming-gateway client walkthrough (stdlib only).

Registers a fresh adapter over HTTP, streams a completion on it
token-by-token (SSE), prints the live adapter table, then unregisters
it — the full runtime adapter lifecycle against a live gateway.

Against an already-running gateway:

  PYTHONPATH=src python -m repro.launch.server --backend sim --port 8080 &
  PYTHONPATH=src python examples/client_stream.py --port 8080

Or self-contained (spawns a sim-backend gateway, runs the flow, drains
it with SIGTERM) — doubling as a smoke test:

  PYTHONPATH=src python examples/client_stream.py --spawn
"""
from __future__ import annotations

import argparse
import http.client
import json
import os
import random
import signal
import subprocess
import sys
import time

# polite-client backoff for 429 (admission refused) and 503 (draining /
# recovering): honor the server's Retry-After when present, otherwise
# exponential backoff, always with jitter so a fleet of clients never
# retries in lockstep
RETRY_STATUSES = (429, 503)
MAX_RETRIES = 6
BASE_BACKOFF = 0.1


def _retry_delay(attempt: int, retry_after, rng) -> float:
    """Server-suggested delay if given, else capped exponential —
    both jittered by up to +25%."""
    if retry_after is not None:
        try:
            base = max(0.001, float(retry_after))
        except ValueError:
            base = BASE_BACKOFF
    else:
        base = min(2.0, BASE_BACKOFF * (2 ** attempt))
    return base * (1.0 + 0.25 * rng.random())


def request(host, port, method, path, body=None, rng=None):
    rng = rng or random.Random()
    for attempt in range(MAX_RETRIES + 1):
        conn = http.client.HTTPConnection(host, port, timeout=30)
        conn.request(method, path,
                     json.dumps(body) if body is not None else None,
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        data = resp.read()
        retry_after = resp.getheader("Retry-After")
        conn.close()
        if resp.status in RETRY_STATUSES and attempt < MAX_RETRIES:
            delay = _retry_delay(attempt, retry_after, rng)
            print(f"  {resp.status} on {method} {path}: "
                  f"retrying in {delay:.3f}s")
            time.sleep(delay)
            continue
        return resp.status, json.loads(data) if data else {}


def stream_completion(host, port, payload, rng=None):
    """POST /v1/completions and yield each SSE data frame as a dict.
    Backs off (honoring Retry-After) on 429/503 before streaming."""
    rng = rng or random.Random()
    for attempt in range(MAX_RETRIES + 1):
        conn = http.client.HTTPConnection(host, port, timeout=60)
        conn.request("POST", "/v1/completions", json.dumps(payload),
                     {"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status in RETRY_STATUSES and attempt < MAX_RETRIES:
            retry_after = resp.getheader("Retry-After")
            resp.read()
            conn.close()
            delay = _retry_delay(attempt, retry_after, rng)
            print(f"  {resp.status} on completion: "
                  f"retrying in {delay:.3f}s")
            time.sleep(delay)
            continue
        break
    assert resp.status == 200, (resp.status, resp.read())
    while True:
        line = resp.fp.readline()
        if not line:
            break
        line = line.decode("utf-8").strip()
        if not line.startswith("data: "):
            continue
        data = line[len("data: "):]
        if data == "[DONE]":
            break
        yield json.loads(data)
    conn.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--spawn", action="store_true",
                    help="spawn a sim-backend gateway, run the flow, "
                         "drain it with SIGTERM")
    args = ap.parse_args()

    proc = None
    host, port = args.host, args.port
    if args.spawn:
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.server",
             "--backend", "sim", "--port", "0"],
            stdout=subprocess.PIPE, text=True, env=env)
        line = proc.stdout.readline().strip()   # "listening on host:port"
        host, port = line.rsplit(" ", 1)[-1].rsplit(":", 1)
        port = int(port)
        print(f"spawned gateway on {host}:{port}")

    try:
        status, health = request(host, port, "GET", "/healthz")
        print(f"healthz: {status} {health}")

        status, created = request(host, port, "POST", "/v1/adapters",
                                  {"adapter_id": "demo-adapter",
                                   "rank": 16})
        print(f"registered: {status} {created}")
        assert status == 201, created

        total = []
        for chunk in stream_completion(host, port, {
                "adapter_id": "demo-adapter", "prompt_len": 16,
                "max_tokens": 8}):
            if chunk.get("finish_reason"):
                print(f"  finish: usage={chunk['usage']}")
            elif chunk.get("tokens"):
                total.extend(chunk["tokens"])
                print(f"  chunk @{chunk['index']}: {chunk['tokens']}")
        print(f"streamed {len(total)} tokens")
        assert len(total) == 8, total

        status, table = request(host, port, "GET", "/v1/adapters")
        print(f"adapter table: {len(table['adapters'])} adapters")

        status, gone = request(host, port, "DELETE",
                               "/v1/adapters/demo-adapter")
        print(f"unregistered: {status} {gone}")
        assert status == 202, gone
        print("client flow OK")
    finally:
        if proc is not None:
            proc.send_signal(signal.SIGTERM)
            try:
                proc.wait(timeout=30)
            except subprocess.TimeoutExpired:
                proc.kill()
            print(f"gateway exited with {proc.returncode}")


if __name__ == "__main__":
    main()
