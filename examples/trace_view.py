"""Flight-recorder tracing walkthrough: attach a ``Tracer`` to a
simulated serving run, export the Perfetto timeline, and read the
per-request phase decomposition straight off the span tree.

  PYTHONPATH=src python examples/trace_view.py

Writes ``/tmp/repro_trace.perfetto.json`` — open it in
https://ui.perfetto.dev to see per-server iteration tracks, the
adapter-store transfer track, and one telescoping
fetch/queue/prefill/decode tree per request.
"""
import copy

from repro.cluster import NetworkModel
from repro.obs import (REQUEST_PHASES, EventClock, FlightRecorder, Tracer,
                       write_perfetto)
from repro.serving import LoRAServeCluster, SimBackend
from repro.traces import make_adapters, synth_trace

OUT = "/tmp/repro_trace.perfetto.json"


def main():
    adapters = make_adapters(16, seed=5)
    trace = synth_trace(adapters, rps=12.0, duration=20.0,
                        prompt_len=256, output_len=32, seed=5)
    nbytes = {a.adapter_id: a.nbytes for a in adapters}

    tracer = Tracer(clock=EventClock())
    recorder = FlightRecorder(capacity=1024, min_interval=0.0)
    backend = SimBackend(2, timeout=60.0, adapter_nbytes=nbytes)
    cluster = LoRAServeCluster(backend, adapters, policy="loraserve",
                               network=NetworkModel(), seed=5,
                               tracer=tracer, flight_recorder=recorder)
    res = cluster.run(copy.deepcopy(trace))

    n = write_perfetto(tracer, OUT)
    print(f"run: {res.completed()}/{len(trace)} requests, "
          f"{tracer.n_spans} spans -> {OUT} ({n} events)")

    # top-5 slowest requests, with the phase breakdown from the span tree
    trees = []
    for req_id, spans in tracer.by_request().items():
        root = next((s for s in spans if s.name == "request"), None)
        if root is None:
            continue
        kids = {s.name: s.duration for s in spans
                if s.parent_id == root.span_id}
        trees.append((root.duration, req_id, root, kids))
    trees.sort(reverse=True)

    print("\nslowest requests (phase decomposition, seconds):")
    hdr = "  ".join(f"{p:>8s}" for p in REQUEST_PHASES)
    print(f"{'req':>5s} {'total':>8s}  {hdr}  adapter")
    for dur, req_id, root, kids in trees[:5]:
        cells = "  ".join(f"{kids.get(p, 0.0):8.3f}" for p in REQUEST_PHASES)
        print(f"{req_id:5d} {dur:8.3f}  {cells}  "
              f"{root.attrs['adapter_id']} (r{root.attrs['rank']})")

    print("\ncost-model drift (sim substrate: bias must be ~0):")
    for phase, d in sorted(res.cost_drift.items()):
        print(f"  {phase:8s} iters={d['count']:6d} "
              f"modeled={d['modeled_s']:8.3f}s bias={d['bias']:+.2e}")

    if recorder.n_dumps:
        print(f"\nflight recorder fired {recorder.n_dumps} dump(s): "
              f"{[r['reason'] for r in recorder.dumps]}")
    else:
        print("\nflight recorder armed, no dump triggers this run "
              f"(ring holds {len(recorder.ring)} spans)")


if __name__ == "__main__":
    main()
