"""Train a base model, then fine-tune a LoRA adapter on it and serve
both through the engine — the full lifecycle that feeds the paper's
serving system.

Defaults train a ~13M-param model for 150 steps on CPU in a few minutes;
scale --steps/--dim up on real hardware (a ~100M model is
--dim 512 --layers 8 --steps 300).

  PYTHONPATH=src python examples/train_lora.py [--steps 150]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.lora.adapter import init_adapter
from repro.models import model as M
from repro.serving import Request, ServingEngine
from repro.training import (AdamWConfig, adamw_init, make_lora_train_step,
                            make_train_step, save_checkpoint)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lora-steps", type=int, default=50)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--rank", type=int, default=16)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("llama-7b-paper"),
                              d_model=args.dim, n_layers=args.layers,
                              n_heads=args.dim // 32,
                              n_kv_heads=args.dim // 32,
                              d_ff=args.dim * 3)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"base model: {n / 1e6:.1f}M params")

    # --- pretrain the base
    oc = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps,
                     weight_decay=0.01)
    step = jax.jit(make_train_step(cfg, oc))
    opt = adamw_init(params)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8, seed=0))
    it = data.batches()
    t0 = time.time()
    for s in range(1, args.steps + 1):
        toks, labels = next(it)
        params, opt, m = step(params, opt, {"tokens": jnp.asarray(toks),
                                            "labels": jnp.asarray(labels)})
        if s % 25 == 0 or s == 1:
            print(f"pretrain step {s:4d} loss={float(m['loss']):.3f} "
                  f"({8 * 64 * s / (time.time() - t0):.0f} tok/s)")

    # --- LoRA fine-tune on a *different* synthetic distribution
    adapter = init_adapter(cfg, args.rank, key)
    aopt = adamw_init(adapter)
    loc = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=args.lora_steps)
    lstep = jax.jit(make_lora_train_step(cfg, loc))
    ft = SyntheticLM(DataConfig(cfg.vocab_size, 64, 8, seed=99)).batches()
    for s in range(1, args.lora_steps + 1):
        toks, labels = next(ft)
        adapter, aopt, m = lstep(adapter, aopt, params,
                                 {"tokens": jnp.asarray(toks),
                                  "labels": jnp.asarray(labels)})
        if s % 25 == 0 or s == 1:
            print(f"lora step {s:4d} loss={float(m['loss']):.3f}")

    save_checkpoint("/tmp/base.msgpack", params)
    save_checkpoint("/tmp/adapter.msgpack", adapter)
    print("checkpoints saved: /tmp/base.msgpack /tmp/adapter.msgpack")

    # --- serve base + adapter together
    engine = ServingEngine(cfg, params, {"base-like": args.rank,
                                         "tuned": args.rank},
                           max_batch=2, max_len=48)
    engine.bank = jax.tree.map(
        lambda bank_t, ad_t: bank_t.at[:, 1].set(ad_t),
        engine.bank, adapter)
    now = time.monotonic()
    engine.submit(Request(0, "base-like", [5, 9, 2, 41], 6, arrival=now))
    engine.submit(Request(1, "tuned", [5, 9, 2, 41], 6, arrival=now))
    summ = engine.run_until_drained()
    print("serving metrics:", {k: round(v, 3) if isinstance(v, float)
                               else v for k, v in summ.items()})


if __name__ == "__main__":
    main()
