"""End-to-end driver (deliverable b): a simulated production cluster
serving a heavy-tailed LoRA trace under all four policies — the paper's
headline experiment (Fig 17) at laptop scale — followed by a real-JAX
mini-cluster (2 engines) routed by the same orchestrator.

  PYTHONPATH=src python examples/serve_cluster.py
"""
import copy
import random
import time

import jax

from repro.cluster import (ClusterSimulator, NetworkModel, ServerModel,
                           profile_operating_points)
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, ClusterOrchestrator
from repro.models import model as M
from repro.serving import Request, ServingEngine
from repro.traces import make_adapters, production_trace


def simulated_cluster():
    print("=== simulated 4-server cluster, production trace, 100 adapters")
    adapters = make_adapters(100, seed=1)
    trace = production_trace(100, rps=20, duration=150, seed=2)
    for pol in ["loraserve", "toppings", "slora-random",
                "slora-contiguous"]:
        sim = ClusterSimulator(4, adapters, policy=pol, seed=3,
                               timeout=60, warmup=40)
        res = sim.run(copy.deepcopy(trace))
        print(f"{pol:18s} p95_ttft={res.p95_ttft():8.3f}s "
              f"tbt={res.mean_tbt() * 1e3:6.1f}ms "
              f"max_adapters/server={res.max_adapters_per_server:3d} "
              f"timeouts={res.timed_out}")


def real_mini_cluster():
    print("=== real-JAX mini cluster (2 engines) behind the orchestrator")
    rng = random.Random(0)
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    adapters = [AdapterInfo(f"ad{i}-r{r}", r, nbytes=r * 2_000_000)
                for i, r in enumerate([8, 8, 32, 64, 128, 128])]
    ranks = {a.adapter_id: a.rank for a in adapters}
    ops = profile_operating_points(ServerModel(),
                                   {a.rank for a in adapters})
    orch = ClusterOrchestrator(2, adapters, ops, policy="loraserve",
                               network=NetworkModel())
    engines = [ServingEngine(cfg, params, ranks, max_batch=4, max_len=40)
               for _ in range(2)]
    for i in range(10):
        aid = rng.choice(adapters).adapter_id
        sid, fetch = orch.route(aid, tokens=20)
        prompt = [rng.randrange(1, cfg.vocab_size) for _ in range(10)]
        engines[sid].submit(Request(i, aid, prompt, 6,
                                    arrival=time.monotonic()))
    for sid, eng in enumerate(engines):
        s = eng.run_until_drained()
        print(f"server {sid}: finished={s['finished']} "
              f"p95_ttft={s['p95_ttft']:.2f}s")
    print(f"pool: fetches={orch.pool.fetches} "
          f"max_adapters/server={orch.pool.max_adapters_per_server()} "
          f"invariant={'OK' if orch.pool.check_invariant() else 'BROKEN'}")


if __name__ == "__main__":
    simulated_cluster()
    real_mini_cluster()
