"""End-to-end driver (deliverable b): the same ``LoRAServeCluster``
facade serving a heavy-tailed LoRA trace under all four policies — first
on the simulated backend (the paper's headline experiment, Fig 17, at
laptop scale), then on a real-JAX mini cluster (2 placement-aware
engines). One API, two substrates.

  PYTHONPATH=src python examples/serve_cluster.py
"""
import copy
import random

import jax

from repro.cluster import NetworkModel
from repro.configs import get_smoke_config
from repro.core import AdapterInfo, ServeRequest
from repro.models import model as M
from repro.serving import EngineBackend, LoRAServeCluster, SimBackend
from repro.traces import make_adapters, production_trace


def simulated_cluster():
    print("=== simulated 4-server cluster, production trace, 100 adapters")
    adapters = make_adapters(100, seed=1)
    trace = production_trace(100, rps=20, duration=150, seed=2)
    nbytes = {a.adapter_id: a.nbytes for a in adapters}
    for pol in ["loraserve", "toppings", "slora-random",
                "slora-contiguous"]:
        backend = SimBackend(4, timeout=60, adapter_nbytes=nbytes)
        cluster = LoRAServeCluster(backend, adapters, policy=pol,
                                   network=NetworkModel(), warmup=40,
                                   seed=3)
        res = cluster.run(copy.deepcopy(trace))
        print(f"{pol:18s} p95_ttft={res.p95_ttft():8.3f}s "
              f"tbt={res.mean_tbt() * 1e3:6.1f}ms "
              f"max_adapters/server={res.max_adapters_per_server:3d} "
              f"rebalances={res.rebalances} timeouts={res.timed_out}")


def real_mini_cluster():
    print("=== real-JAX mini cluster (2 engines) behind the same facade")
    rng = random.Random(0)
    cfg = get_smoke_config("llama-7b-paper")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    adapters = [AdapterInfo(f"ad{i}-r{r}", r, nbytes=r * 2_000_000)
                for i, r in enumerate([8, 8, 32, 64, 128, 128])]
    backend = EngineBackend(cfg, params, 2, max_batch=4, max_len=40)
    cluster = LoRAServeCluster(backend, adapters, policy="loraserve",
                               network=NetworkModel(),
                               rebalance_period=2.0)
    trace = []
    for i in range(10):
        a = rng.choice(adapters)
        prompt = [rng.randrange(1, cfg.vocab_size) for _ in range(10)]
        trace.append(ServeRequest(req_id=i, adapter_id=a.adapter_id,
                                  rank=a.rank, prompt_len=10,
                                  output_len=6, prompt=prompt,
                                  arrival=i * 0.3))
    res = cluster.run(trace)
    for sid in range(2):
        mem = res.memory_profile[sid]
        print(f"server {sid}: requests={res.per_server_counts[sid]} "
              f"bank_max_rank={mem['max_rank']}")
    print(f"finished={res.completed()}/10 "
          f"p95_ttft={res.summary['p95_ttft']:.2f}s "
          f"pool: fetches={res.fetches} "
          f"max_adapters/server={res.max_adapters_per_server} "
          f"invariant="
          f"{'OK' if cluster.orch.pool.check_invariant() else 'BROKEN'}")


if __name__ == "__main__":
    simulated_cluster()
    real_mini_cluster()
