"""Placement explorer: visualize (ASCII) what Algorithm 1 does to a
skewed workload vs the baselines — the paper's Fig 12 intuition.

  PYTHONPATH=src python examples/placement_explorer.py
"""
from repro.cluster import ServerModel, profile_operating_points
from repro.core import (AdapterInfo, PlacementContext, POLICIES,
                        servers_to_adapters)


def main():
    ranks = [8] * 6 + [16] * 4 + [32] * 3 + [64] * 2 + [128] * 2
    adapters = [AdapterInfo(f"a{i:02d}-r{r}", r) for i, r in
                enumerate(ranks)]
    # heavy-tailed demand: first adapter of each rank is hot
    demand = {}
    seen = set()
    for a in adapters:
        hot = a.rank not in seen
        seen.add(a.rank)
        demand[a.adapter_id] = 3000.0 if hot else 40.0
    ops = profile_operating_points(ServerModel(), set(ranks))
    ctx = PlacementContext(n_servers=4, adapters=adapters,
                           demand_tps=demand, operating_points=ops)

    for pol_name in ["loraserve", "slora-random", "slora-contiguous"]:
        placement = POLICIES[pol_name]().place(ctx)
        print(f"\n=== {pol_name}")
        by_server = servers_to_adapters(placement)
        for sid in range(4):
            aids = by_server.get(sid, [])
            util = sum(demand[a] / ops[next(x.rank for x in adapters
                                            if x.adapter_id == a)]
                       for a in aids
                       for _ in [0]) if aids else 0
            load = sum(demand[a] * placement[a][sid] for a in aids)
            ranks_here = sorted({int(a.split("-r")[1]) for a in aids})
            print(f"  server {sid}: {len(aids):2d} adapters "
                  f"ranks={ranks_here} load={load:8.0f} tok/s")
            hot = [f"{a}(phi={placement[a][sid]:.2f})" for a in aids
                   if demand[a] > 100]
            if hot:
                print(f"            hot: {', '.join(hot)}")


if __name__ == "__main__":
    main()
